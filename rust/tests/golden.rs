//! Golden-vector stability: the canonical streams must never change across
//! refactors (they are the cross-language interchange contract with
//! python/tests/test_golden.py and the PJRT artifacts), and the bulk
//! slice-fill path must be bit-identical to the scalar path.
//!
//! The committed vectors under tests/golden/ are produced by
//! python/tools/gen_golden_vectors.py — an independent transliteration
//! driven through the NumPy oracles of python/compile/kernels/ref.py,
//! pinned to published splitmix64 / MT19937 test vectors.

mod common;

use common::{fnv64, read_fillpath};
use xorgens_gp::prng::xorwow::Xorwow;
use xorgens_gp::prng::{
    make_generator, BlockParallel, GeneratorKind, Mt19937, Prng32, Xorgens, XorgensGp,
};

const GOLDEN_N: usize = 4096;
const GOLDEN_SEEDS: [u64; 2] = [20260710, 424242];

/// The tentpole invariant: for every generator kind, the stream produced
/// through the bulk fill path (`fill_u32`, any chunking) is byte-identical
/// to scalar `next_u32` draws — and both match the committed
/// cross-language golden vector.
#[test]
fn fill_path_bit_identical_to_scalar_and_golden() {
    for kind in GeneratorKind::ALL {
        for seed in GOLDEN_SEEDS {
            // Scalar path.
            let mut g = make_generator(kind, seed);
            let scalar: Vec<u32> = (0..GOLDEN_N).map(|_| g.next_u32()).collect();
            // Bulk path: one contiguous fill.
            let mut g = make_generator(kind, seed);
            let mut bulk = vec![0u32; GOLDEN_N];
            g.fill_u32(&mut bulk);
            assert_eq!(bulk, scalar, "{kind}/{seed}: bulk fill != scalar");
            // Bulk path: uneven chunking (primes, to cross every round
            // boundary misaligned).
            let mut g = make_generator(kind, seed);
            let mut chunked = vec![0u32; GOLDEN_N];
            let mut i = 0;
            for (k, chunk) in [1usize, 31, 127, 1009, 2048].iter().cycle().enumerate() {
                if i >= GOLDEN_N {
                    break;
                }
                let take = (*chunk + k % 3).min(GOLDEN_N - i);
                g.fill_u32(&mut chunked[i..i + take]);
                i += take;
            }
            assert_eq!(chunked, scalar, "{kind}/{seed}: chunked fill != scalar");
            // Committed golden vector.
            let (head, hash) = read_fillpath(kind.name(), seed);
            assert_eq!(&scalar[..32], &head[..], "{kind}/{seed}: head != committed vector");
            assert_eq!(fnv64(&scalar), hash, "{kind}/{seed}: fnv64 != committed vector");
        }
    }
}

/// MT19937 reference vector (published; also asserted against NumPy in
/// python/tests/test_kernels.py).
#[test]
fn mt19937_seed_5489_vector() {
    let mut mt = Mt19937::new(5489);
    let expect: [u32; 10] = [
        3499211612, 581869302, 3890346734, 3586334585, 545404204, 4161255391, 3922919429,
        949333985, 2715962298, 1323567403,
    ];
    for (i, &e) in expect.iter().enumerate() {
        assert_eq!(mt.next_u32(), e, "output {i}");
    }
}

/// Frozen first outputs of the seeded generators. These pin our seeding
/// scheme (SeedSequence + warmup): if any change, every golden file,
/// artifact state, and EXPERIMENTS.md run would silently diverge.
#[test]
fn frozen_xorgens_stream() {
    let mut g = Xorgens::new(20260710);
    let first: Vec<u32> = (0..4).map(|_| g.next_u32()).collect();
    let recorded = record_or_check("xorgens-20260710", &first);
    assert_eq!(first, recorded);
}

#[test]
fn frozen_xorwow_stream() {
    let mut g = Xorwow::new(20260710);
    let first: Vec<u32> = (0..4).map(|_| g.next_u32()).collect();
    let recorded = record_or_check("xorwow-20260710", &first);
    assert_eq!(first, recorded);
}

#[test]
fn frozen_xorgensgp_round() {
    let mut g = XorgensGp::new(20260710, 2);
    let mut out = vec![0u32; g.round_len()];
    g.fill_round(&mut out);
    let first: Vec<u32> = out[..4].to_vec();
    let recorded = record_or_check("xorgensgp-20260710", &first);
    assert_eq!(first, recorded);
}

/// First run records into tests/golden/frozen-<name>.txt; later runs
/// compare. (The recorded files are committed alongside.)
fn record_or_check(name: &str, values: &[u32]) -> Vec<u32> {
    let dir = std::path::Path::new("tests/golden");
    let path = dir.join(format!("frozen-{name}.txt"));
    if let Ok(text) = std::fs::read_to_string(&path) {
        text.split_whitespace().map(|t| t.parse().expect("golden file corrupt")).collect()
    } else {
        std::fs::create_dir_all(dir).expect("mkdir golden");
        let text: Vec<String> = values.iter().map(|v| v.to_string()).collect();
        std::fs::write(&path, text.join(" ")).expect("write golden");
        values.to_vec()
    }
}

/// The golden JSON files written by `cargo run -- golden` must match what
/// the generators produce now (guards the CLI dump path itself).
#[test]
fn golden_json_files_consistent() {
    let path = std::path::Path::new("tests/golden/xorgensgp.json");
    if !path.exists() {
        eprintln!("SKIP: run `cargo run --release -- golden` first");
        return;
    }
    let text = std::fs::read_to_string(path).unwrap();
    let blocks = extract_int(&text, "\"blocks\":") as usize;
    assert_eq!(blocks, 3);
    // Regenerate and compare the outputs array.
    let mut gen = XorgensGp::new(20260710, 3);
    let mut out = vec![0u32; 4 * gen.round_len()];
    gen.fill_interleaved(&mut out);
    let outputs_section = text.split("\"outputs\":[").nth(1).unwrap();
    let n_outputs = outputs_section.trim_end_matches(&[']', '}'][..]).split(',').count();
    assert_eq!(n_outputs, out.len());
    assert!(outputs_section.starts_with(&out[0].to_string()));
}

fn extract_int(text: &str, key: &str) -> i64 {
    let idx = text.find(key).expect("key present") + key.len();
    text[idx..].chars().take_while(|c| c.is_ascii_digit()).collect::<String>().parse().unwrap()
}

//! SIMD fill-kernel integration suite: every kernel this CPU can run must
//! serve the **exact scalar stream** — the committed golden vectors,
//! property-tested odd-sized chunked consumption with continuation across
//! `fill_round` boundaries, the threaded fill engine, and placed
//! (leapfrog) streams.
//!
//! Every test here flips the process-wide kernel selector
//! ([`xorgens_gp::simd::set_forced`]), so they all serialize on one mutex
//! and restore `auto` on the way out. (Bit-identity makes a concurrent
//! observer harmless — the serialization just keeps each assertion's
//! kernel label truthful.)

mod common;

use common::{fnv64, read_fillpath};
use std::sync::{Mutex, MutexGuard};
use xorgens_gp::prng::traits::InterleavedStream;
use xorgens_gp::prng::xorwow::XorwowBlock;
use xorgens_gp::prng::{
    make_block_generator, make_generator, BlockParallel, GeneratorKind, LeapfrogBlock, Prng32,
};
use xorgens_gp::simd::{self, KernelChoice, SimdKernel};
use xorgens_gp::util::prop::check;

const GOLDEN_SEEDS: [u64; 2] = [20260710, 424242];

static FORCE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` once per available kernel with that kernel forced; restores
/// auto selection afterwards. Forcing an *available* kernel must never
/// clamp.
fn with_kernels(f: impl Fn(SimdKernel)) {
    let _guard = lock();
    for k in simd::available_kernels() {
        assert_eq!(simd::set_forced(KernelChoice::Force(k)), k, "{k} clamped");
        assert_eq!(simd::active_kernel(), k);
        f(k);
    }
    simd::set_forced(KernelChoice::Auto);
}

/// The headline pin: under every forced kernel, every generator kind
/// serves the committed cross-language fillpath goldens bit for bit at
/// both seeds — the SIMD kernels are a pure data-layout transform.
#[test]
fn every_available_kernel_serves_the_committed_goldens() {
    with_kernels(|k| {
        for kind in GeneratorKind::ALL {
            for seed in GOLDEN_SEEDS {
                let mut g = make_generator(kind, seed);
                let mut out = vec![0u32; 4096];
                g.fill_u32(&mut out);
                let (head, hash) = read_fillpath(kind.name(), seed);
                assert_eq!(&out[..32], &head[..], "{kind}/{seed} kernel={k}: head != golden");
                assert_eq!(fnv64(&out), hash, "{kind}/{seed} kernel={k}: fnv64 != golden");
            }
        }
    });
}

/// Property: for every paper kind × available kernel, a stream consumed
/// in random odd-sized chunks (continuation carried across `fill_round`
/// boundaries by the interleaving buffer) is bit-identical to the same
/// stream under the forced-scalar reference kernel.
#[test]
fn kernels_match_scalar_across_odd_chunked_streams() {
    let _guard = lock();
    let kernels = simd::available_kernels();
    check("simd-vs-scalar-chunked", 16, 0x51_4d_44, |c| {
        let kind = GeneratorKind::PAPER_SET[c.range(0, 2)];
        let blocks = c.range(1, 9);
        let seed = c.u64();
        // Odd total, spanning at least one round boundary most of the
        // time (mtgp round_len at 9 blocks is 2043).
        let total = c.range(3, 5000) | 1;
        let mut chunks = Vec::new();
        let mut left = total;
        while left > 0 {
            let take = c.range(1, left.min(797));
            chunks.push(take);
            left -= take;
        }
        let run = |k: SimdKernel| -> Vec<u32> {
            simd::set_forced(KernelChoice::Force(k));
            let mut g = InterleavedStream::new(make_block_generator(kind, seed, blocks));
            let mut out = vec![0u32; total];
            let mut i = 0;
            for &ch in &chunks {
                g.fill_u32(&mut out[i..i + ch]);
                i += ch;
            }
            out
        };
        let reference = run(SimdKernel::Scalar);
        for &k in &kernels {
            assert_eq!(
                run(k),
                reference,
                "kind={kind} blocks={blocks} total={total} kernel={k}"
            );
        }
    });
    simd::set_forced(KernelChoice::Auto);
}

/// SIMD × threads compose: the parallel fill engine (`fill_threads 3`,
/// odd so the 64-block partition is uneven) under every forced kernel
/// still serves the committed goldens.
#[test]
fn threaded_fills_serve_goldens_under_every_kernel() {
    with_kernels(|k| {
        for (kind, golden) in
            [(GeneratorKind::XorgensGp, "xorgensgp"), (GeneratorKind::Mtgp, "mtgp")]
        {
            for seed in GOLDEN_SEEDS {
                let mut g = make_block_generator(kind, seed, 64);
                let round = g.round_len();
                // Whole rounds covering the 4096-word golden span.
                let rounds = 4096usize.div_ceil(round).max(2);
                let mut out = vec![0u32; rounds * round];
                g.fill_interleaved_threaded(3, &mut out);
                let (head, hash) = read_fillpath(golden, seed);
                assert_eq!(&out[..32], &head[..], "{kind}/{seed} kernel={k} threaded head");
                assert_eq!(fnv64(&out[..4096]), hash, "{kind}/{seed} kernel={k} threaded fnv");
            }
        }
    });
}

/// XORWOW's threaded worker parts vectorize across blocks; under every
/// kernel the threaded fill must match the serial fill (and the serial
/// fill is tied to scalar by the chunked property above).
#[test]
fn xorwow_threaded_matches_serial_under_every_kernel() {
    with_kernels(|k| {
        for blocks in [3usize, 17, 64] {
            let mut a = XorwowBlock::new(99, blocks);
            let mut b = XorwowBlock::new(99, blocks);
            let mut oa = vec![0u32; 64 * a.round_len()];
            let mut ob = vec![0u32; 64 * b.round_len()];
            a.fill_interleaved(&mut oa);
            b.fill_interleaved_threaded(3, &mut ob);
            assert_eq!(oa, ob, "blocks={blocks} kernel={k}");
        }
    });
}

/// Placement is kernel-invariant: a leapfrog-dealt stream re-interleaves
/// to exactly the serial master sequence under every forced kernel (the
/// paper's placement contract, unchanged by vectorization).
#[test]
fn leapfrog_placement_is_kernel_invariant() {
    with_kernels(|k| {
        for kind in GeneratorKind::PAPER_SET {
            let mut lf = LeapfrogBlock::new(make_block_generator(kind, 7, 1), 5);
            let mut out = vec![0u32; 4 * lf.round_len()];
            lf.fill_interleaved(&mut out);
            let mut serial = InterleavedStream::new(make_block_generator(kind, 7, 1));
            let mut expect = vec![0u32; out.len()];
            serial.fill_u32(&mut expect);
            assert_eq!(out, expect, "kind={kind} kernel={k}: leapfrog != serial master");
        }
    });
}

/// The env override parses the same names the CLI does, and unavailable
/// forced kernels clamp to the detected best (never panic, never silently
/// change the stream — which the golden pins above already prove).
#[test]
fn forcing_unavailable_kernels_clamps_to_detected() {
    let _guard = lock();
    for k in SimdKernel::ALL {
        let got = simd::set_forced(KernelChoice::Force(k));
        if k.is_available() {
            assert_eq!(got, k);
        } else {
            assert_eq!(got, simd::detect(), "unavailable {k} must clamp to detected");
        }
        assert!(got.is_available());
    }
    assert_eq!(simd::set_forced(KernelChoice::Auto), simd::detect());
}

//! Property tests (via the in-crate `util::prop` driver — proptest is
//! unavailable offline) on the coordinator and generator invariants.

use xorgens_gp::coordinator::batcher::{plan_batch, PendingRequest};
use xorgens_gp::prng::params::XorgensParams;
use xorgens_gp::prng::traits::InterleavedStream;
use xorgens_gp::prng::{BlockParallel, Mtgp, Prng32, Xorgens, XorgensGp};
use xorgens_gp::util::prop::check;

/// Batcher conservation: buffered + launches*launch_size == served + leftover,
/// FIFO order, no request dropped or duplicated.
#[test]
fn prop_batcher_conserves_outputs() {
    check("batcher-conservation", 500, 1, |c| {
        let n_reqs = c.range(0, 12);
        let requests: Vec<PendingRequest> = (0..n_reqs)
            .map(|i| PendingRequest { request_id: i as u64, n: c.range(0, 5000) })
            .collect();
        let buffered = c.range(0, 2000);
        let launch_size = c.range(1, 4096);
        let plan = plan_batch(&requests, buffered, launch_size);
        let total: usize = requests.iter().map(|r| r.n).sum();
        // Conservation.
        assert_eq!(buffered + plan.launches * launch_size, total + plan.leftover);
        // No over-launching: one fewer launch would not cover demand.
        if plan.launches > 0 {
            assert!(buffered + (plan.launches - 1) * launch_size < total);
        }
        // FIFO, complete, no duplicates.
        let ids: Vec<u64> = plan.allocations.iter().map(|a| a.0).collect();
        let expect: Vec<u64> = requests.iter().map(|r| r.request_id).collect();
        assert_eq!(ids, expect);
    });
}

/// Block-parallel xorgensGP == serial xorgens per block, for random block
/// counts and round counts (the paper's §2 equivalence).
#[test]
fn prop_xorgensgp_blocks_equal_serial() {
    check("gp-vs-serial", 25, 2, |c| {
        let blocks = c.range(1, 4);
        let seed = c.u64();
        let mut gp = XorgensGp::new(seed, blocks);
        let state = gp.dump_state();
        let r = gp.params().r;
        let mut serials: Vec<Xorgens> = (0..blocks)
            .map(|b| {
                let s = &state[b * (r + 1)..(b + 1) * (r + 1)];
                Xorgens::from_canonical_state(gp.params(), &s[..r], s[r])
            })
            .collect();
        let rounds = c.range(1, 8);
        let mut out = vec![0u32; gp.round_len()];
        for _ in 0..rounds {
            gp.fill_round(&mut out);
            for (b, serial) in serials.iter_mut().enumerate() {
                for j in 0..gp.lane_width() {
                    assert_eq!(out[b * gp.lane_width() + j], serial.next_u32());
                }
            }
        }
    });
}

/// dump_state/load_state round-trips preserve the stream exactly.
#[test]
fn prop_state_roundtrip_preserves_stream() {
    check("state-roundtrip", 20, 3, |c| {
        let seed = c.u64();
        let blocks = c.range(1, 3);
        let mut a = XorgensGp::new(seed, blocks);
        // advance a random number of rounds to desync from canonical
        let mut sink = vec![0u32; a.round_len()];
        for _ in 0..c.range(0, 5) {
            a.fill_round(&mut sink);
        }
        let st = a.dump_state();
        let mut b = XorgensGp::new(seed ^ 1, blocks);
        b.load_state(&st);
        let mut oa = vec![0u32; 3 * a.round_len()];
        let mut ob = vec![0u32; 3 * a.round_len()];
        a.fill_interleaved(&mut oa);
        b.fill_interleaved(&mut ob);
        assert_eq!(oa, ob);
    });
}

/// The InterleavedStream adapter never drops or reorders values.
#[test]
fn prop_interleaved_stream_faithful() {
    check("interleaved-faithful", 20, 4, |c| {
        let seed = c.u64();
        let blocks = c.range(1, 3);
        let mut direct = Mtgp::new(seed, blocks);
        let mut adapter = InterleavedStream::new(Mtgp::new(seed, blocks));
        let round = direct.round_len();
        let mut expect = vec![0u32; 2 * round];
        direct.fill_round(&mut expect[..round]);
        direct.fill_round(&mut expect[round..]);
        // Draw the same total via mixed-size fills.
        let mut got = Vec::new();
        while got.len() < expect.len() {
            let k = c.range(1, 97).min(expect.len() - got.len());
            let mut buf = vec![0u32; k];
            adapter.fill_u32(&mut buf);
            got.extend(buf);
        }
        assert_eq!(got, expect);
    });
}

/// The bulk-fill contract for every generator kind: `fill_u32` over
/// arbitrary chunk sizes equals one contiguous fill equals scalar draws.
#[test]
fn prop_chunked_fill_equals_contiguous_fill() {
    use xorgens_gp::prng::make_generator;
    use xorgens_gp::prng::GeneratorKind;
    check("chunked-fill", 10, 8, |c| {
        let seed = c.u64();
        let total = c.range(1, 3000);
        for kind in GeneratorKind::ALL {
            // One contiguous fill.
            let mut contiguous = vec![0u32; total];
            make_generator(kind, seed).fill_u32(&mut contiguous);
            // Scalar draws.
            let mut scalar_gen = make_generator(kind, seed);
            let scalar: Vec<u32> = (0..total).map(|_| scalar_gen.next_u32()).collect();
            assert_eq!(contiguous, scalar, "{kind}: contiguous fill != scalar");
            // Arbitrary chunking.
            let mut chunked_gen = make_generator(kind, seed);
            let mut chunked = Vec::with_capacity(total);
            while chunked.len() < total {
                let k = c.range(1, 257).min(total - chunked.len());
                let mut buf = vec![0u32; k];
                chunked_gen.fill_u32(&mut buf);
                chunked.extend(buf);
            }
            assert_eq!(chunked, contiguous, "{kind}: chunked fill diverged");
        }
    });
}

/// The parallel fill engine serves the serial interleaved stream bit for
/// bit: every paper generator × thread counts (including more workers
/// than blocks) × random block/round geometry, and the generator state
/// continues identically afterwards.
#[test]
fn prop_threaded_fill_matches_serial() {
    use xorgens_gp::exec::fill_rounds_parallel;
    use xorgens_gp::prng::{make_block_generator, GeneratorKind};
    check("threaded-fill", 12, 9, |c| {
        let seed = c.u64();
        let blocks = c.range(2, 9);
        let rounds = c.range(1, 12);
        for kind in GeneratorKind::PAPER_SET {
            for threads in [1usize, 2, 3, 7] {
                let mut serial = make_block_generator(kind, seed, blocks);
                let mut threaded = make_block_generator(kind, seed, blocks);
                let n = rounds * serial.round_len();
                let mut a = vec![0u32; n];
                let mut b = vec![0u32; n];
                serial.fill_interleaved(&mut a);
                // Drive the engine directly (no crossover threshold), so
                // small geometries genuinely split; threads=1 declines and
                // falls back, which must serve the same stream.
                if !fill_rounds_parallel(&mut *threaded, threads, &mut b) {
                    threaded.fill_interleaved(&mut b);
                }
                assert_eq!(a, b, "{kind}: threads={threads} blocks={blocks} rounds={rounds}");
                // Continuation: both generators advanced identically.
                let round = serial.round_len();
                let (mut a2, mut b2) = (vec![0u32; round], vec![0u32; round]);
                serial.fill_round(&mut a2);
                threaded.fill_round(&mut b2);
                assert_eq!(a2, b2, "{kind}: continuation diverged after threaded fill");
            }
        }
    });
}

/// The trait-level threaded entry point over the crossover threshold,
/// with odd (non-round-multiple) buffer sizes: identical stream to
/// `fill_interleaved`, including the discarded-excess tail contract —
/// and the leapfrog wrapper (no split) falls back without tearing.
#[test]
fn prop_fill_interleaved_threaded_matches_serial_above_threshold() {
    use xorgens_gp::exec::PAR_FILL_MIN_WORDS;
    use xorgens_gp::prng::{make_block_generator, GeneratorKind, LeapfrogBlock};
    check("threaded-odd-sizes", 4, 10, |c| {
        let seed = c.u64();
        let threads = c.range(2, 6);
        for kind in GeneratorKind::PAPER_SET {
            let blocks = c.range(2, 6);
            let mut serial = make_block_generator(kind, seed, blocks);
            let mut threaded = make_block_generator(kind, seed, blocks);
            let round = serial.round_len();
            // Above the crossover and not a multiple of the round length:
            // the engine fills the whole-rounds span threaded and bounces
            // the partial tail.
            let n = PAR_FILL_MIN_WORDS + round + c.range(1, round.max(2) - 1);
            let mut a = vec![0u32; n];
            let mut b = vec![0u32; n];
            serial.fill_interleaved(&mut a);
            threaded.fill_interleaved_threaded(threads, &mut b);
            assert_eq!(a, b, "{kind}: threads={threads} blocks={blocks} n={n}");
        }
        // Leapfrog deals one master round-robin — inherently serial; the
        // threaded entry point must decline the split and fall back.
        let vblocks = c.range(2, 5);
        let mk = || LeapfrogBlock::new(make_block_generator(GeneratorKind::XorgensGp, seed, 1), vblocks);
        let (mut serial, mut threaded) = (mk(), mk());
        let round = serial.round_len();
        let n = (PAR_FILL_MIN_WORDS / round + 1) * round + 7;
        let mut a = vec![0u32; n];
        let mut b = vec![0u32; n];
        serial.fill_interleaved(&mut a);
        threaded.fill_interleaved_threaded(3, &mut b);
        assert_eq!(a, b, "leapfrog fallback diverged");
    });
}

/// Seed avalanche: flipping any single bit of the seed decorrelates
/// the first outputs (~50% differing bits).
#[test]
fn prop_seed_avalanche() {
    check("seed-avalanche", 40, 5, |c| {
        let seed = c.u64();
        let bit = c.range(0, 63);
        let mut g1 = Xorgens::new(seed);
        let mut g2 = Xorgens::new(seed ^ (1u64 << bit));
        let mut diff = 0u32;
        const N: usize = 32;
        for _ in 0..N {
            diff += (g1.next_u32() ^ g2.next_u32()).count_ones();
        }
        let frac = diff as f64 / (N as f64 * 32.0);
        assert!((0.35..0.65).contains(&frac), "seed bit {bit}: diff fraction {frac}");
    });
}

/// Small-parameter xorgens: maximal-period sets found by the search
/// satisfy the recurrence over a window.
#[test]
fn prop_small_params_recurrence() {
    let sets = xorgens_gp::prng::params::find_small_params(2, 1, 3);
    assert!(!sets.is_empty());
    check("small-params", 10, 6, |c| {
        let p = sets[c.range(0, sets.len() - 1)];
        let seed = c.u64();
        let mut g = Xorgens::with_params(seed, p);
        let mut hist: Vec<u32> = (0..p.r).map(|_| g.step_raw()).collect();
        for _ in 0..64 {
            let k = hist.len();
            let mut t = hist[k - p.r];
            let mut v = hist[k - p.s];
            t ^= t << p.a;
            t ^= t >> p.b;
            v ^= v << p.c;
            v ^= v >> p.d;
            let got = g.step_raw();
            assert_eq!(got, v ^ t);
            hist.push(got);
        }
    });
}

/// Validation accepts exactly the structurally-good parameter sets.
#[test]
fn prop_param_validation() {
    check("param-validation", 300, 7, |c| {
        let r = 1usize << c.range(1, 8);
        let s = c.range(1, (r - 1).max(1));
        let p = XorgensParams {
            r,
            s,
            a: c.range(0, 33) as u32,
            b: c.range(0, 33) as u32,
            c: c.range(0, 33) as u32,
            d: c.range(0, 33) as u32,
        };
        let ok = p.validate().is_ok();
        let expect = p.r.is_power_of_two()
            && p.r >= 2
            && p.s > 0
            && p.s < p.r
            && gcd(p.r, p.s) == 1
            && [p.a, p.b, p.c, p.d].iter().all(|&x| x >= 1 && x < 32);
        assert_eq!(ok, expect, "{p:?}");
    });
}

/// Cluster lease/placement cross-check: for random shard counts, the
/// leased slot ranges are pairwise disjoint, and a shard registry
/// (confined to its lease) agrees bit for bit with a standalone registry
/// about the placed states of the same *global* slot (exact-jump) and
/// the derived seed of the same global stream id (leapfrog/seed-mix) —
/// the two identities the router pins before picking a shard.
#[test]
fn prop_cluster_leases_disjoint_and_placement_identical() {
    use xorgens_gp::cluster::shard_slot_range;
    use xorgens_gp::coordinator::{Placement, StreamConfig, StreamRegistry};
    use xorgens_gp::prng::init::SeedSequence;
    use xorgens_gp::prng::GeneratorKind;
    check("cluster-lease-placement", 6, 11, |c| {
        let shards = c.range(2, 6) as u64;
        let ranges: Vec<std::ops::Range<u64>> =
            (0..shards).map(|j| shard_slot_range(j).unwrap()).collect();
        for (i, a) in ranges.iter().enumerate() {
            assert_eq!(a.end - a.start, 1u64 << 32, "shard {i} lease is not 2^32 slots");
            for b in ranges.iter().skip(i + 1) {
                assert!(a.end <= b.start || b.end <= a.start, "leases overlap: {a:?} {b:?}");
            }
        }
        // Exact-jump: same global slot => same placed states, whichever
        // registry computed them.
        let root = c.u64();
        let j = c.range(1, shards as usize - 1) as u64;
        let blocks = c.range(1, 3);
        let exact = |slot_base| StreamConfig {
            kind: GeneratorKind::Xorwow,
            placement: Placement::ExactJump { log2_spacing: 40 },
            blocks,
            slot_base,
            ..Default::default()
        };
        let shard_reg = StreamRegistry::with_slot_range(root, shard_slot_range(j).unwrap());
        let a = shard_reg.register_checked("a", exact(None)).unwrap();
        let global_slot = shard_reg.slot_base(a).unwrap();
        assert_eq!(global_slot, ranges[j as usize].start, "lease start not honored");
        let single = StreamRegistry::new(root);
        let b = single.register_checked("b", exact(Some(global_slot))).unwrap();
        assert_eq!(
            shard_reg.placed_block_states(a).unwrap(),
            single.placed_block_states(b).unwrap(),
            "shard-local placement != single-registry placement at slot {global_slot}"
        );
        // Leapfrog: identity is the derived seed; the router's explicit
        // pin for global id `gid` equals the standalone derivation.
        let gid = c.range(0, 40) as u64;
        let pinned = SeedSequence::new(root).child(gid).next_u64();
        let leap = |seed| StreamConfig { placement: Placement::Leapfrog, seed, ..Default::default() };
        let sh = shard_reg.register_checked("lf", leap(Some(pinned))).unwrap();
        assert_eq!(shard_reg.stream_seed(sh), pinned);
        let solo = StreamRegistry::new(root);
        for g in 0..gid {
            solo.register_checked(&format!("pad-{g}"), leap(None)).unwrap();
        }
        let si = solo.register_checked("lf", leap(None)).unwrap();
        assert_eq!(solo.stream_seed(si), pinned, "router seed pin != derivation at id {gid}");
    });
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

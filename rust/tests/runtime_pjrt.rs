//! Cross-layer integration: the PJRT-executed AOT artifacts (JAX + Pallas,
//! compiled by `make artifacts`) must be bit-exact with the pure-Rust
//! generators from the same canonical state.
//!
//! This is the load-bearing test of the three-layer architecture: L1
//! (Pallas kernel) ≡ L2 (JAX graph) ≡ L3 (Rust backend), one stream of
//! truth. Skips (with a note) when artifacts have not been built.

use xorgens_gp::prng::xorwow::XorwowBlock;
use xorgens_gp::prng::{BlockParallel, Mtgp, XorgensGp};
use xorgens_gp::runtime::{default_dir, PjrtRuntime, Transform};

fn runtime_or_skip() -> Option<PjrtRuntime> {
    if !cfg!(all(feature = "pjrt", xla_vendored)) {
        eprintln!(
            "SKIP: built without the real PJRT client (needs `--features pjrt` AND a \
             vendored xla crate; launches would stub-error)"
        );
        return None;
    }
    let dir = default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::new(&dir).expect("PJRT runtime"))
}

/// Drive a BlockParallel generator and the artifact side by side.
fn check_bit_exact(
    rt: &mut PjrtRuntime,
    artifact: &str,
    gen: &mut dyn BlockParallel,
    launches: usize,
) {
    let meta = rt.manifest.find(artifact).expect("artifact in manifest").clone();
    for launch in 0..launches {
        let state = gen.dump_state();
        let (new_state, out) = rt.launch(artifact, &state).expect("launch");
        // Rust generator produces the same stream via the bulk fill path.
        let mut expect = vec![0u32; meta.rounds * gen.round_len()];
        gen.fill_interleaved(&mut expect);
        let got = out.as_u32().expect("u32 artifact");
        assert_eq!(got.len(), expect.len(), "launch {launch} output size");
        assert_eq!(got, &expect[..], "launch {launch} outputs differ");
        // And the same post-launch state.
        assert_eq!(new_state, gen.dump_state(), "launch {launch} state differs");
    }
}

#[test]
fn xorgensgp_artifact_bit_exact_with_rust() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut gen = XorgensGp::new(20260710, 8);
    check_bit_exact(&mut rt, "xorgensgp_u32_b8_r2", &mut gen, 3);
}

#[test]
fn mtgp_artifact_bit_exact_with_rust() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut gen = Mtgp::new(20260710, 4);
    check_bit_exact(&mut rt, "mtgp_u32_b4_r2", &mut gen, 3);
}

#[test]
fn xorwow_artifact_bit_exact_with_rust() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut gen = XorwowBlock::new(20260710, 16);
    check_bit_exact(&mut rt, "xorwow_u32_b16_s32", &mut gen, 3);
}

#[test]
fn f32_artifact_matches_u32_scaling() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Launch u32 and f32 artifacts from the same state: f32 = (u >> 8) * 2^-24.
    let gen = XorgensGp::new(7, 64);
    let state = gen.dump_state();
    let (_, out_u) = rt.launch("xorgensgp_u32_b64_r16", &state).unwrap();
    let (_, out_f) = rt.launch("xorgensgp_f32_b64_r16", &state).unwrap();
    let us = out_u.as_u32().unwrap();
    let fs = out_f.as_f32().unwrap();
    assert_eq!(us.len(), fs.len());
    for (i, (&u, &f)) in us.iter().zip(fs).enumerate() {
        let expect = (u >> 8) as f32 * (1.0 / 16_777_216.0);
        assert_eq!(f, expect, "index {i}");
    }
}

#[test]
fn normal_artifact_moments() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let gen = XorgensGp::new(99, 64);
    let state = gen.dump_state();
    let (_, out) = rt.launch("xorgensgp_normal_b64_r16", &state).unwrap();
    let z = out.as_f32().unwrap();
    let n = z.len() as f64;
    let mean = z.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = z.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    assert!(mean.abs() < 0.02, "mean {mean}");
    assert!((var - 1.0).abs() < 0.03, "var {var}");
}

#[test]
fn manifest_best_for_picks_production_shapes() {
    let Some(rt) = runtime_or_skip() else { return };
    use xorgens_gp::prng::GeneratorKind;
    let best = rt.manifest.best_for(GeneratorKind::XorgensGp, Transform::U32).unwrap();
    assert_eq!(best.outputs, 64 * 64 * 63); // §Perf L2-1 launch shape
    let best = rt.manifest.best_for(GeneratorKind::Xorwow, Transform::U32).unwrap();
    assert_eq!(best.outputs, 256 * 256);
}

#[test]
fn state_continuity_across_launches() {
    // Two consecutive launches must continue the stream exactly (state
    // round-trip) — the coordinator depends on this.
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut gen = XorgensGp::new(5, 8);
    let s0 = gen.dump_state();
    let (s1, out1) = rt.launch("xorgensgp_u32_b8_r2", &s0).unwrap();
    let (_, out2) = rt.launch("xorgensgp_u32_b8_r2", &s1).unwrap();
    // Rust side: 4 rounds total.
    let mut expect = vec![0u32; 4 * gen.round_len()];
    gen.fill_interleaved(&mut expect);
    let mut got = out1.as_u32().unwrap().to_vec();
    got.extend_from_slice(out2.as_u32().unwrap());
    assert_eq!(got, expect);
}

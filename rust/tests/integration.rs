//! End-to-end integration across modules: coordinator over both backends,
//! battery-over-coordinator streams, device model consistency with the
//! measured generators.

use std::sync::Arc;
use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Draws};
use xorgens_gp::prng::{BlockParallel, GeneratorKind, XorgensGp};
use xorgens_gp::runtime::Transform;
use xorgens_gp::testu01::battery::{run_battery, Tier};

fn artifacts_built() -> bool {
    // The stub runtime (no `pjrt` feature, or no vendored xla) errors at
    // launch, so PJRT-backed serving tests only run when the real client is
    // compiled in too.
    cfg!(all(feature = "pjrt", xla_vendored))
        && xorgens_gp::runtime::default_dir().join("manifest.txt").exists()
}

/// The full serving path over the PJRT backend: rust coordinator ->
/// dynamic batcher -> AOT JAX/Pallas artifact -> clients. Python is not
/// involved at any point of this test's runtime.
#[test]
fn coordinator_pjrt_backend_serves() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let s = coord
        .builder("pjrt-stream")
        .backend(BackendKind::Pjrt)
        .u32()
        .expect("stream");
    let v = s.draw(300_000).expect("draw over PJRT");
    assert_eq!(v.len(), 300_000);
    let m = coord.metrics();
    // best artifact is xorgensgp_u32_b64_r64 (258048/launch) -> 2 launches.
    assert!(m.launches >= 2, "expected >=2 launches of 258048: {}", m.launches);
    coord.shutdown();
}

/// Rust and PJRT backends serve the *same stream* for the same stream name
/// (bit-exact cross-backend reproducibility — the core architectural
/// claim).
#[test]
fn rust_and_pjrt_backends_bit_exact() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let cfg = CoordinatorConfig { workers: 1, ..Default::default() };
    let c1 = Coordinator::new(cfg.clone());
    let c2 = Coordinator::new(cfg);
    // Same stream name -> same derived seed. The Rust stream must use the
    // PJRT artifact's launch shape (64 blocks, 16 rounds) to walk the
    // blocks in the same phase.
    let s1 = c1
        .builder("shared-name")
        .backend(BackendKind::Rust)
        .blocks(64)
        .rounds_per_launch(16)
        .u32()
        .expect("rust stream");
    let s2 = c2
        .builder("shared-name")
        .backend(BackendKind::Pjrt)
        .u32()
        .expect("pjrt stream");
    let a = s1.draw(70_000).unwrap();
    let b = s2.draw(70_000).unwrap();
    assert_eq!(a, b);
    c1.shutdown();
    c2.shutdown();
}

/// Backpressure: with a tiny queue and non-blocking mode, a flood of
/// requests is partially rejected rather than deadlocking.
#[test]
fn backpressure_rejects_when_full() {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers: 1,
        queue_depth: 2,
        block_on_full: false,
        ..Default::default()
    }));
    let mut oks = 0;
    let mut rejected = 0;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..16 {
            let c = coord.clone();
            handles.push(scope.spawn(move || {
                let s = c.builder("flood").blocks(1).u32().expect("stream");
                s.draw(500_000).is_ok()
            }));
        }
        for h in handles {
            if h.join().unwrap() {
                oks += 1;
            } else {
                rejected += 1;
            }
        }
    });
    assert!(oks >= 1, "some requests must succeed");
    assert_eq!(oks as u64 + rejected as u64, 16);
    // Metrics reflect the rejections (if any occurred under this timing).
    assert_eq!(coord.metrics().rejected, rejected);
}

/// Deterministic backpressure accounting: occupy the single worker with a
/// large draw, fill the one-slot queue, and every further submit must (a)
/// return an error and (b) increment `metrics.rejected` — the
/// rejected-vs-error bookkeeping cannot drift apart.
#[test]
fn backpressure_rejection_increments_metric_and_errors() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        queue_depth: 1,
        block_on_full: false,
        ..Default::default()
    });
    let s = coord.builder("bp").blocks(1).rounds_per_launch(1).u32().expect("stream");
    // 8M draws from a 63-word launch: the worker is busy for many
    // milliseconds, far longer than the microseconds these submits take.
    let big = s.submit(8_000_000).expect("first submit");
    let mut held = Vec::new();
    let mut rejections = 0u64;
    let mut first_err = None;
    for _ in 0..3 {
        match s.submit(1000) {
            Ok(t) => held.push(t), // filled the queue slot
            Err(e) => {
                rejections += 1;
                first_err.get_or_insert(e);
            }
        }
    }
    assert!(rejections >= 1, "three submits against a busy worker and a 1-deep queue must reject");
    let err = first_err.unwrap();
    assert!(format!("{err}").contains("backpressure"), "{err}");
    assert_eq!(coord.metrics().rejected, rejections, "metric must match observed rejections");
    // The accepted requests still complete.
    assert_eq!(big.wait().expect("big draw").len(), 8_000_000);
    for t in held {
        assert_eq!(t.wait().expect("held draw").len(), 1000);
    }
    coord.shutdown();
}

/// Shutdown with in-flight pipelined requests: `shutdown()` neither hangs
/// nor drops replies — every ticket submitted before shutdown still
/// delivers its full draw (the worker drains its queue before exiting).
#[test]
fn shutdown_with_inflight_requests_drops_nothing() {
    let coord = Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() });
    let s1 = coord.builder("sd-a").blocks(2).rounds_per_launch(1).u32().expect("stream");
    let s2 = coord.builder("sd-b").blocks(2).normal().expect("stream");
    let tickets: Vec<_> = (0..6).map(|i| s1.submit(1000 + i).expect("submit")).collect();
    let f_tickets: Vec<_> = (0..4).map(|_| s2.submit(500).expect("submit")).collect();
    // Consumes the coordinator: sends Shutdown to every shard and joins the
    // workers. Queued draws are FIFO-ahead of the Shutdown message.
    coord.shutdown();
    for (i, t) in tickets.into_iter().enumerate() {
        let v = t.wait().expect("reply delivered despite shutdown");
        assert_eq!(v.len(), 1000 + i);
    }
    for t in f_tickets {
        assert_eq!(t.wait().expect("f32 reply delivered").len(), 500);
    }
}

/// Dropping the coordinator (instead of calling `shutdown()`) also joins
/// the workers without hanging; handles cannot outlive it — the borrow in
/// `TypedStream<'c, T>` makes use-after-shutdown a compile error, which is
/// the third leg of the typed API's misuse-prevention story.
#[test]
fn drop_joins_workers_cleanly() {
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let s = coord.builder("sd-late").blocks(1).u32().expect("stream");
    assert_eq!(s.draw(64).expect("live draw").len(), 64);
    drop(coord); // Drop impl sends Shutdown and joins
}

/// A coordinator stream passes the SmallCrush tier — serving does not
/// damage statistical quality (buffering/slicing bugs would).
#[test]
fn coordinator_stream_passes_smallcrush() {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() }));
    let s = coord.builder("quality").blocks(4).u32().expect("stream").id();
    struct CoordRng {
        coord: Arc<Coordinator>,
        stream: xorgens_gp::coordinator::StreamId,
        buf: Vec<u32>,
        pos: usize,
    }
    impl xorgens_gp::prng::Prng32 for CoordRng {
        fn next_u32(&mut self) -> u32 {
            if self.pos == self.buf.len() {
                // Re-attach a typed handle to the registered stream and
                // refill the reader's buffer in place (pool-recycled).
                if self.buf.is_empty() {
                    self.buf = vec![0u32; 65536];
                }
                let h = self.coord.typed::<u32>(self.stream).expect("typed attach");
                h.draw_into(&mut self.buf).expect("draw");
                self.pos = 0;
            }
            let v = self.buf[self.pos];
            self.pos += 1;
            v
        }
        fn name(&self) -> &'static str {
            "coordinator-stream"
        }
        fn state_words(&self) -> usize {
            129
        }
        fn period_log2(&self) -> f64 {
            4128.0
        }
    }
    let mut rng = CoordRng { coord: coord.clone(), stream: s, buf: Vec::new(), pos: 0 };
    // A couple of representative instances rather than the full tier
    // (runtime); full-tier runs live in the battery CLI / benches.
    let r = xorgens_gp::testu01::collision::collision(&mut rng, 1 << 13, 24);
    assert!(!r.is_fail(), "collision p={}", r.p_value);
    let r = xorgens_gp::testu01::hamming::hamming_weight(&mut rng, 1 << 16);
    assert!(!r.is_fail(), "weight p={}", r.p_value);
    let r = xorgens_gp::testu01::linear_complexity::linear_complexity_test(&mut rng, 20_000, 2);
    assert!(!r.is_fail(), "lincomp p={}", r.p_value);
}

/// Device model: the footprints it assumes agree with the implemented
/// generators (guards drift between model constants and the real code).
#[test]
fn device_model_footprints_match_generators() {
    use xorgens_gp::device::GeneratorKernelProfile;
    let gp = XorgensGp::new(1, 1);
    let prof = GeneratorKernelProfile::xorgens_gp();
    assert_eq!(prof.resources.shared_mem_per_block as usize, gp.state_words_per_block() * 4 + 8);
    // MTGP: paper Table 1 footprint is a 1024-word padded buffer; our
    // generator's true state is 624 words <= 1024.
    let mtgp = xorgens_gp::prng::Mtgp::new(1, 1);
    let prof = GeneratorKernelProfile::mtgp();
    assert!(mtgp.state_words_per_block() * 4 <= prof.resources.shared_mem_per_block as usize);
    // XORWOW: 6 words, no shared memory.
    assert_eq!(GeneratorKernelProfile::xorwow().resources.shared_mem_per_block, 0);
}

/// The full SmallCrush tier passes for the paper's generator over the
/// actual serving stream shapes (single-block per-stream).
#[test]
fn smallcrush_via_battery_api() {
    let report = run_battery(Tier::Small, GeneratorKind::XorgensGp, 424242);
    assert!(report.failures().is_empty(), "{}", report.render(true));
}

/// Draw type safety end to end: the typed terminals produce the declared
/// element types, attach-time validation rejects the one mismatch the
/// types cannot rule out, and the deprecated untyped surface still carries
/// the matching `Draws` variant.
#[test]
fn transform_type_safety() {
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let su = coord.builder("u").u32().expect("stream");
    let sf = coord.builder("f").uniform().expect("stream");
    assert_eq!(su.draw(10).unwrap().len(), 10);
    assert_eq!(sf.draw(10).unwrap().len(), 10);
    assert_eq!(su.transform(), Transform::U32);
    assert_eq!(sf.transform(), Transform::F32);
    // Cross-attach: rejected with a typed error before any draw.
    assert!(coord.typed::<f32>(su.id()).is_err());
    assert!(coord.typed::<u32>(sf.id()).is_err());
    // Legacy untyped surface carries the declared variant.
    #[allow(deprecated)]
    {
        match coord.draw(su.id(), 10).unwrap() {
            Draws::U32(v) => assert_eq!(v.len(), 10),
            Draws::F32(_) => panic!("wrong type"),
        }
        match coord.draw(sf.id(), 10).unwrap() {
            Draws::F32(v) => assert_eq!(v.len(), 10),
            Draws::U32(_) => panic!("wrong type"),
        }
    }
    coord.shutdown();
}

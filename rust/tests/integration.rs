//! End-to-end integration across modules: coordinator over both backends,
//! battery-over-coordinator streams, device model consistency with the
//! measured generators.

use std::sync::Arc;
use xorgens_gp::coordinator::{
    BackendKind, Coordinator, CoordinatorConfig, Draws, StreamConfig,
};
use xorgens_gp::prng::{BlockParallel, GeneratorKind, XorgensGp};
use xorgens_gp::runtime::Transform;
use xorgens_gp::testu01::battery::{run_battery, Tier};

fn artifacts_built() -> bool {
    // The stub runtime (no `pjrt` feature) errors at launch, so PJRT-backed
    // serving tests only run when the feature is compiled in too.
    cfg!(feature = "pjrt") && xorgens_gp::runtime::default_dir().join("manifest.txt").exists()
}

/// The full serving path over the PJRT backend: rust coordinator ->
/// dynamic batcher -> AOT JAX/Pallas artifact -> clients. Python is not
/// involved at any point of this test's runtime.
#[test]
fn coordinator_pjrt_backend_serves() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let s = coord.stream(
        "pjrt-stream",
        StreamConfig { backend: BackendKind::Pjrt, ..Default::default() },
    );
    let v = coord.draw_u32(s, 300_000).expect("draw over PJRT");
    assert_eq!(v.len(), 300_000);
    let m = coord.metrics();
    // best artifact is xorgensgp_u32_b64_r64 (258048/launch) -> 2 launches.
    assert!(m.launches >= 2, "expected >=2 launches of 258048: {}", m.launches);
    coord.shutdown();
}

/// Rust and PJRT backends serve the *same stream* for the same stream name
/// (bit-exact cross-backend reproducibility — the core architectural
/// claim).
#[test]
fn rust_and_pjrt_backends_bit_exact() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let cfg = CoordinatorConfig { workers: 1, ..Default::default() };
    let c1 = Coordinator::new(cfg.clone());
    let c2 = Coordinator::new(cfg);
    // Same stream name -> same derived seed. The Rust stream must use the
    // PJRT artifact's launch shape (64 blocks, 16 rounds) to walk the
    // blocks in the same phase.
    let s1 = c1.stream(
        "shared-name",
        StreamConfig {
            backend: BackendKind::Rust,
            blocks: 64,
            rounds_per_launch: 16,
            ..Default::default()
        },
    );
    let s2 = c2.stream(
        "shared-name",
        StreamConfig { backend: BackendKind::Pjrt, ..Default::default() },
    );
    let a = c1.draw_u32(s1, 70_000).unwrap();
    let b = c2.draw_u32(s2, 70_000).unwrap();
    assert_eq!(a, b);
    c1.shutdown();
    c2.shutdown();
}

/// Backpressure: with a tiny queue and non-blocking mode, a flood of
/// requests is partially rejected rather than deadlocking.
#[test]
fn backpressure_rejects_when_full() {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers: 1,
        queue_depth: 2,
        block_on_full: false,
        ..Default::default()
    }));
    let s = coord.stream("flood", StreamConfig { blocks: 1, ..Default::default() });
    let mut oks = 0;
    let mut rejected = 0;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..16 {
            let c = coord.clone();
            handles.push(scope.spawn(move || c.draw(s, 500_000).is_ok()));
        }
        for h in handles {
            if h.join().unwrap() {
                oks += 1;
            } else {
                rejected += 1;
            }
        }
    });
    assert!(oks >= 1, "some requests must succeed");
    assert_eq!(oks as u64 + rejected as u64, 16);
    // Metrics reflect the rejections (if any occurred under this timing).
    assert_eq!(coord.metrics().rejected, rejected);
}

/// A coordinator stream passes the SmallCrush tier — serving does not
/// damage statistical quality (buffering/slicing bugs would).
#[test]
fn coordinator_stream_passes_smallcrush() {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() }));
    let s = coord.stream("quality", StreamConfig { blocks: 4, ..Default::default() });
    struct CoordRng {
        coord: Arc<Coordinator>,
        stream: xorgens_gp::coordinator::StreamId,
        buf: Vec<u32>,
        pos: usize,
    }
    impl xorgens_gp::prng::Prng32 for CoordRng {
        fn next_u32(&mut self) -> u32 {
            if self.pos == self.buf.len() {
                self.buf = self.coord.draw_u32(self.stream, 65536).expect("draw");
                self.pos = 0;
            }
            let v = self.buf[self.pos];
            self.pos += 1;
            v
        }
        fn name(&self) -> &'static str {
            "coordinator-stream"
        }
        fn state_words(&self) -> usize {
            129
        }
        fn period_log2(&self) -> f64 {
            4128.0
        }
    }
    let mut rng = CoordRng { coord: coord.clone(), stream: s, buf: Vec::new(), pos: 0 };
    // A couple of representative instances rather than the full tier
    // (runtime); full-tier runs live in the battery CLI / benches.
    let r = xorgens_gp::testu01::collision::collision(&mut rng, 1 << 13, 24);
    assert!(!r.is_fail(), "collision p={}", r.p_value);
    let r = xorgens_gp::testu01::hamming::hamming_weight(&mut rng, 1 << 16);
    assert!(!r.is_fail(), "weight p={}", r.p_value);
    let r = xorgens_gp::testu01::linear_complexity::linear_complexity_test(&mut rng, 20_000, 2);
    assert!(!r.is_fail(), "lincomp p={}", r.p_value);
}

/// Device model: the footprints it assumes agree with the implemented
/// generators (guards drift between model constants and the real code).
#[test]
fn device_model_footprints_match_generators() {
    use xorgens_gp::device::GeneratorKernelProfile;
    let gp = XorgensGp::new(1, 1);
    let prof = GeneratorKernelProfile::xorgens_gp();
    assert_eq!(prof.resources.shared_mem_per_block as usize, gp.state_words_per_block() * 4 + 8);
    // MTGP: paper Table 1 footprint is a 1024-word padded buffer; our
    // generator's true state is 624 words <= 1024.
    let mtgp = xorgens_gp::prng::Mtgp::new(1, 1);
    let prof = GeneratorKernelProfile::mtgp();
    assert!(mtgp.state_words_per_block() * 4 <= prof.resources.shared_mem_per_block as usize);
    // XORWOW: 6 words, no shared memory.
    assert_eq!(GeneratorKernelProfile::xorwow().resources.shared_mem_per_block, 0);
}

/// The full SmallCrush tier passes for the paper's generator over the
/// actual serving stream shapes (single-block per-stream).
#[test]
fn smallcrush_via_battery_api() {
    let report = run_battery(Tier::Small, GeneratorKind::XorgensGp, 424242);
    assert!(report.failures().is_empty(), "{}", report.render(true));
}

/// Draw type safety: transforms produce the declared types end to end.
#[test]
fn transform_type_safety() {
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let su = coord.stream("u", StreamConfig { transform: Transform::U32, ..Default::default() });
    let sf = coord.stream("f", StreamConfig { transform: Transform::F32, ..Default::default() });
    match coord.draw(su, 10).unwrap() {
        Draws::U32(v) => assert_eq!(v.len(), 10),
        Draws::F32(_) => panic!("wrong type"),
    }
    match coord.draw(sf, 10).unwrap() {
        Draws::F32(v) => assert_eq!(v.len(), 10),
        Draws::U32(_) => panic!("wrong type"),
    }
    coord.shutdown();
}

//! Failure injection: malformed artifacts, wrong state sizes, failing
//! backends — the error paths a production deployment hits.

use std::io::Write;
use xorgens_gp::bail;
use xorgens_gp::coordinator::{Backend, Draws};
use xorgens_gp::runtime::{Manifest, PjrtRuntime, Transform};
use xorgens_gp::util::error::Result;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("xorgensgp-fi-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_clean_error() {
    let dir = tmpdir("nomanifest");
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}

#[test]
fn malformed_manifest_lines_rejected() {
    let dir = tmpdir("malformed");
    for (i, line) in [
        "too few fields",
        "name kind u32 64 16 63 64512 2",                   // bad generator kind
        "name xorgensgp wat 64 16 63 64512 2",              // bad transform
        "name xorgensgp u32 64 16 63 999 2",                // inconsistent outputs
        "name xorgensgp u32 64 16 63 64512 2",              // file missing
    ]
    .iter()
    .enumerate()
    {
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        writeln!(f, "{line}").unwrap();
        drop(f);
        let res = Manifest::load(&dir);
        assert!(res.is_err(), "case {i} should fail: {line}");
    }
}

#[test]
fn comments_and_blank_lines_ok() {
    let dir = tmpdir("comments");
    std::fs::write(dir.join("manifest.txt"), "# header\n\n# another\n").unwrap();
    let m = Manifest::load(&dir).unwrap();
    assert!(m.artifacts.is_empty());
}

#[test]
fn corrupt_hlo_text_fails_at_launch() {
    // Without the `pjrt` feature the stub errors at launch (clear
    // feature-disabled message); with it, HLO parsing fails. Either way
    // the artifact name is in the message and manifest loading succeeded.
    let dir = tmpdir("corrupt");
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO").unwrap();
    std::fs::write(dir.join("manifest.txt"), "bad xorgensgp u32 1 1 63 63 2\n").unwrap();
    let mut rt = PjrtRuntime::new(&dir).expect("manifest load independent of artifacts");
    let err = rt.launch("bad", &vec![1u32; 129]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad"), "{msg}");
}

#[cfg(not(all(feature = "pjrt", xla_vendored)))]
#[test]
fn stub_rejects_wrong_state_size_before_launch() {
    // State validation happens before the feature-disabled error in the
    // stub (the real client validates after HLO compilation instead).
    let dir = tmpdir("statesize");
    std::fs::write(dir.join("s.hlo.txt"), "HLO placeholder").unwrap();
    std::fs::write(dir.join("manifest.txt"), "s xorwow u32 4 1 1 4 2\n").unwrap();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let err = rt.launch("s", &[0u32; 7]).unwrap_err();
    assert!(format!("{err:#}").contains("state size mismatch"), "{err:#}");
}

#[test]
fn wrong_state_size_rejected() {
    let dir = xorgens_gp::runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let err = rt.launch("xorgensgp_u32_b8_r2", &[0u32; 7]).unwrap_err();
    assert!(format!("{err:#}").contains("state size mismatch"));
}

#[test]
fn unknown_artifact_name_rejected() {
    let dir = xorgens_gp::runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    assert!(rt.launch("nope", &[]).is_err());
}

/// A backend that fails after k launches: the coordinator must surface the
/// error to every affected request and stay alive for other streams.
struct FailAfter {
    left: usize,
}

impl Backend for FailAfter {
    fn launch_size(&self) -> usize {
        64
    }
    fn transform(&self) -> Transform {
        Transform::U32
    }
    fn launch_into(&mut self, out: &mut Draws) -> Result<()> {
        if self.left == 0 {
            bail!("injected failure");
        }
        self.left -= 1;
        out.extend(Draws::U32(vec![7; 64]));
        Ok(())
    }
    fn describe(&self) -> String {
        "failing".into()
    }
}

#[test]
fn failing_backend_surfaces_error() {
    // Drive the Backend trait directly (the coordinator wiring for custom
    // backends is exercised via the service tests; here we pin the trait
    // contract: launch_into appends on success and leaves the buffer
    // unchanged on failure, and the provided launch() wraps it).
    let mut b = FailAfter { left: 3 };
    let d = b.launch().expect("provided launch() delegates to launch_into");
    assert_eq!(d.len(), 64);
    let mut acc = Draws::U32(vec![]);
    assert!(b.launch_into(&mut acc).is_ok());
    assert!(b.launch_into(&mut acc).is_ok());
    assert_eq!(acc.len(), 128);
    let err = b.launch_into(&mut acc).unwrap_err();
    assert!(format!("{err}").contains("injected failure"));
    // acc unchanged after failure.
    assert_eq!(acc.len(), 128);
}

/// Backend construction failures surface through the typed-handle surface:
/// a PJRT stream with no artifacts errors on `draw` AND on a pipelined
/// ticket's `wait`, with the actionable message intact, and the
/// coordinator stays alive for other streams.
#[test]
fn typed_handle_surfaces_backend_failure() {
    use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        artifact_dir: tmpdir("no-artifacts"),
        ..Default::default()
    });
    let broken = coord
        .builder("broken")
        .backend(BackendKind::Pjrt)
        .u32()
        .expect("building the handle is fine; the backend materialises on first draw");
    let err = broken.draw(100).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
    // Pipelined path: the error arrives through the ticket.
    let t = broken.submit(100).expect("submit enqueues fine");
    let err = t.wait().unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
    // Other streams on the same worker are unaffected.
    let healthy = coord.builder("healthy").blocks(2).u32().expect("stream");
    assert_eq!(healthy.draw(64).expect("healthy draw").len(), 64);
    coord.shutdown();
}

/// Generator constructor contracts.
#[test]
fn constructor_contracts() {
    use xorgens_gp::prng::params::XorgensParams;
    // Invalid parameter sets panic with a clear message.
    let res = std::panic::catch_unwind(|| {
        xorgens_gp::prng::Xorgens::with_params(1, XorgensParams { s: 64, ..XorgensParams::GP_4096 })
    });
    assert!(res.is_err());
    // Zero LFSR state rejected.
    let res = std::panic::catch_unwind(|| {
        xorgens_gp::prng::xorwow::Xorwow::from_state([0; 5], 1)
    });
    assert!(res.is_err());
}

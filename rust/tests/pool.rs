//! Persistent fill-pool + generation-ahead prefetch integration suite:
//! a prefetching coordinator must serve the committed golden streams
//! unchanged for every paper kind and every pool width, the pooled
//! `ShardServer` must stay bit-identical through the router, the
//! connection cap must queue (not drop) excess clients, and the
//! prefetch counters must be observable through the `stats` wire verb.

mod common;

use common::{fnv64, read_fillpath};
use std::time::Duration;
use xorgens_gp::cluster::{
    Router, RouterConfig, ShardClient, ShardServer, ShardServerConfig,
};
use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig, StreamConfig};
use xorgens_gp::prng::traits::InterleavedStream;
use xorgens_gp::prng::xorwow::XorwowBlock;
use xorgens_gp::prng::{GeneratorKind, Placement, Prng32};

const GOLDEN_SEEDS: [u64; 2] = [20260710, 424242];

fn pooled_coord(fill_threads: usize, prefetch: usize) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        workers: 2,
        fill_threads,
        prefetch,
        ..Default::default()
    })
}

/// The headline pin: a generation-ahead coordinator serves the committed
/// cross-language golden vectors bit for bit, for every kind with a
/// block-interleaved golden file, at pool widths 1 and 3 (odd, so the
/// 64-block partition is uneven) and launch sizes on both sides of the
/// engine's crossover.
#[test]
fn prefetched_coordinator_serves_committed_goldens() {
    let cases = [
        (GeneratorKind::XorgensGp, "xorgensgp"),
        (GeneratorKind::Xorgens, "xorgensgp"),
        (GeneratorKind::Mtgp, "mtgp"),
        (GeneratorKind::Mt19937, "mtgp"),
    ];
    for fill_threads in [1usize, 3] {
        for (kind, golden) in cases {
            for seed in GOLDEN_SEEDS {
                let c = pooled_coord(fill_threads, 1);
                for (name, rounds) in [("g-small", 1usize), ("g-big", 16)] {
                    let s = c
                        .builder(name)
                        .kind(kind)
                        .seed(seed)
                        .blocks(64)
                        .rounds_per_launch(rounds)
                        .u32()
                        .unwrap();
                    let got = s.draw(4096).unwrap();
                    let (head, hash) = read_fillpath(golden, seed);
                    assert_eq!(
                        &got[..32],
                        &head[..],
                        "{kind}/{seed} threads={fill_threads} rounds={rounds}: head != golden"
                    );
                    assert_eq!(
                        fnv64(&got),
                        hash,
                        "{kind}/{seed} threads={fill_threads} rounds={rounds}: fnv64 != golden"
                    );
                }
                c.shutdown();
            }
        }
    }
}

/// XORWOW has no block-interleaved golden file; pin the prefetched stream
/// against the library construction the backend documents, at both pool
/// widths and with a per-stream prefetch-depth override.
#[test]
fn prefetched_xorwow_matches_library_construction() {
    for fill_threads in [1usize, 3] {
        for depth in [1usize, 2] {
            for seed in GOLDEN_SEEDS {
                let c = pooled_coord(fill_threads, 0);
                let s = c
                    .builder("xw-pool")
                    .kind(GeneratorKind::Xorwow)
                    .seed(seed)
                    .blocks(16)
                    .rounds_per_launch(8)
                    .prefetch(depth)
                    .u32()
                    .unwrap();
                let got = s.draw(4096).unwrap();
                let mut oracle = InterleavedStream::new(XorwowBlock::new(seed, 16));
                let expect: Vec<u32> = (0..4096).map(|_| oracle.next_u32()).collect();
                assert_eq!(got, expect, "seed {seed} threads={fill_threads} depth={depth}");
                c.shutdown();
            }
        }
    }
}

/// Draw sequences crossing launch boundaries are identical with and
/// without generation-ahead, for every paper kind — the prefetch buffer
/// swap cannot drop, duplicate, or reorder a single word.
#[test]
fn prefetch_bit_identical_across_launch_boundaries() {
    for kind in GeneratorKind::PAPER_SET {
        let base = Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() });
        let ahead = pooled_coord(3, 2);
        let b = base.builder("seq").kind(kind).blocks(8).rounds_per_launch(4).u32().unwrap();
        let a = ahead.builder("seq").kind(kind).blocks(8).rounds_per_launch(4).u32().unwrap();
        for n in [100usize, 1009, 4096, 333] {
            assert_eq!(b.draw(n).unwrap(), a.draw(n).unwrap(), "{kind}: diverged at draw({n})");
        }
        base.shutdown();
        ahead.shutdown();
    }
}

/// Shutting a coordinator down while streams still hold inflight
/// generation-ahead jobs must drain cleanly — no hang, no panic.
#[test]
fn coordinator_shutdown_with_prefetch_inflight_is_clean() {
    let c = pooled_coord(3, 2);
    let s = c.builder("inflight").blocks(64).rounds_per_launch(4).u32().unwrap();
    // One draw leaves a background generate job in flight for this stream.
    assert_eq!(s.draw(500).unwrap().len(), 500);
    c.shutdown();
}

fn pooled_shard(id: u64) -> ShardServer {
    ShardServer::bind(
        "127.0.0.1:0",
        ShardServerConfig {
            shard_id: id,
            coordinator: CoordinatorConfig {
                workers: 2,
                fill_threads: 3,
                prefetch: 1,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

/// The routed-cluster bit-identity holds when every shard runs a pooled,
/// prefetching coordinator: same streams as one *plain* local coordinator
/// with the same root seed, for all paper kinds under both placements.
#[test]
fn pooled_cluster_bit_identical_to_plain_local_coordinator() {
    let s0 = pooled_shard(0);
    let s1 = pooled_shard(1);
    let router = Router::connect(RouterConfig {
        shards: vec![s0.addr().to_string(), s1.addr().to_string()],
        ..Default::default()
    })
    .unwrap();
    let local = Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() });
    for kind in GeneratorKind::PAPER_SET {
        for placement in [Placement::SeedMix, Placement::ExactJump { log2_spacing: 40 }] {
            let name = format!("{kind}-{placement:?}");
            let routed = router
                .builder(&name)
                .kind(kind)
                .blocks(4)
                .rounds_per_launch(2)
                .placement(placement)
                .u32()
                .unwrap();
            let direct = local
                .builder(&name)
                .kind(kind)
                .blocks(4)
                .rounds_per_launch(2)
                .placement(placement)
                .u32()
                .unwrap();
            for n in [100usize, 1009] {
                assert_eq!(
                    routed.draw(n).unwrap(),
                    direct.draw(n).unwrap(),
                    "{name}: pooled routed != plain local at draw({n})"
                );
            }
        }
    }
    local.shutdown();
    router.shutdown_shards();
}

/// `max_connections: 1` queues the second client in the listener backlog
/// instead of dropping it: both concurrent clients are eventually served
/// the correct stream.
#[test]
fn connection_cap_queues_clients_without_dropping() {
    let server = ShardServer::bind(
        "127.0.0.1:0",
        ShardServerConfig {
            shard_id: 0,
            coordinator: CoordinatorConfig { workers: 2, ..Default::default() },
            max_connections: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr().to_string();
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client =
                    ShardClient::connect(&addr, Duration::from_secs(30)).unwrap();
                let (id, _) = client
                    .register(
                        &format!("capped-{i}"),
                        StreamConfig { blocks: 4, rounds_per_launch: 2, ..Default::default() },
                    )
                    .unwrap();
                let draws = client.draw(id, 777).unwrap();
                assert_eq!(draws.len(), 777);
                // Dropping the client closes the socket, freeing the
                // single handler slot for the queued peer.
            })
        })
        .collect();
    for w in workers {
        w.join().expect("capped client failed");
    }
    server.stop();
}

/// The generation-ahead counters surface through the `stats` wire verb:
/// after draws on a prefetching shard, the JSON snapshot reports the
/// (at least one) cold-start stall and any steady-state hits.
#[test]
fn prefetch_counters_visible_through_stats_verb() {
    let server = pooled_shard(0);
    let addr = server.addr().to_string();
    let mut client = ShardClient::connect(&addr, Duration::from_secs(30)).unwrap();
    let (id, _) = client
        .register(
            "stats-stream",
            StreamConfig { blocks: 64, rounds_per_launch: 4, ..Default::default() },
        )
        .unwrap();
    for _ in 0..8 {
        assert_eq!(client.draw(id, 500).unwrap().len(), 500);
    }
    let json = client.stats().unwrap();
    for key in ["\"prefetch_hits\":", "\"prefetch_stalls\":", "\"pool_queue_depth\":"] {
        assert!(json.contains(key), "stats missing {key}: {json}");
    }
    // Refilling the ready buffer at least once means at least one stall
    // (the cold start) or hit was recorded.
    let activity = extract_int(&json, "\"prefetch_hits\":")
        + extract_int(&json, "\"prefetch_stalls\":");
    assert!(activity >= 1, "no prefetch activity recorded: {json}");
    drop(client);
    server.stop();
}

/// Pull the integer after `key` out of a flat JSON object string.
fn extract_int(json: &str, key: &str) -> u64 {
    let tail = json.split(key).nth(1).unwrap_or_else(|| panic!("{key} not in {json}"));
    let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap_or_else(|_| panic!("no integer after {key} in {json}"))
}

/// The `pool_queue_depth` gauge is maintained live at the enqueue and
/// dequeue sites (not recomputed at snapshot time), so a quiescent pool
/// must read exactly zero: every part a completed dispatch queued has
/// been popped, and prefetch 0 leaves no background jobs behind.
#[test]
fn pool_queue_depth_gauge_drains_to_zero() {
    let c = pooled_coord(4, 0);
    let s = c.builder("gauge").blocks(64).rounds_per_launch(16).u32().unwrap();
    for _ in 0..4 {
        // 64 blocks × 16 rounds × 63 words = one full launch above the
        // parallel-fill crossover: parts genuinely flow through the queue.
        assert_eq!(s.draw(64512).unwrap().len(), 64512);
    }
    assert_eq!(c.metrics().pool_queue_depth, 0, "gauge must drain to zero at quiescence");
    c.shutdown();
}

/// Per-worker telemetry sums exactly to the fan-out the launches
/// dispatched: with 64 blocks and a 4-lane pool (3 workers + the
/// dispatching caller), every launch splits into exactly 4 parts —
/// wherever each part actually ran (worker pop or caller help-steal).
#[test]
fn worker_part_counts_sum_to_launch_fanout() {
    use std::sync::atomic::Ordering;
    let c = pooled_coord(4, 0);
    let s = c
        .builder("fanout")
        .kind(GeneratorKind::XorgensGp)
        .blocks(64)
        .rounds_per_launch(16)
        .u32()
        .unwrap();
    for _ in 0..6 {
        assert_eq!(s.draw(64512).unwrap().len(), 64512);
    }
    let exp = c.exposition();
    let launches = exp.global.launches;
    assert!(launches >= 6, "expected one launch per full-launch draw, got {launches}");
    let parts: u64 = exp.workers.iter().map(|w| w.parts.load(Ordering::Relaxed)).sum();
    assert_eq!(
        parts,
        launches * 4,
        "64-block launches over a 4-lane pool must split into exactly 4 parts each"
    );
    // The trailing slot is the caller's: it ran part 0 of every dispatch.
    let caller = exp.workers.last().expect("caller slot");
    assert!(
        caller.parts.load(Ordering::Relaxed) >= launches,
        "caller slot must have run part 0 of every launch"
    );
    c.shutdown();
}
